"""Certification-as-a-service: a continuous-batching RunSpec server.

This package turns the one-shot batch machinery of ``repro.api`` into a
long-lived serving layer — the "millions of users" direction of the
roadmap.  RunSpec JSON payloads stream in; verdicts + ledger summaries
stream out; in between:

    submission queue     repro.serve.queue      admission control, spec
                                                deserialization, eager
                                                plan-time validation,
                                                plan -> Cell splitting
    coalescing scheduler repro.serve.scheduler  pools cells by group_key
                                                (jaxpr structure x
                                                backend x channel x
                                                rounds), flushes on
                                                max_batch or deadline
    compiled-program     repro.serve.cache      LRU over group keys; the
    cache                                       jitted group runners
                                                survive across batches,
                                                hit/miss == compile
                                                avoided/paid
    result stream        repro.serve.service    verdict per eps + wire
                                                bits per spec, per-client
                                                submission order

Not to be confused with ``repro.launch.serve`` — the LM token-decoding
driver (KV-cache batched greedy decode for the model zoo).  That serves
*tokens from one model*; this serves *certification verdicts for many
RunSpecs*, and only this one speaks the paper's communication-bound
machinery.

CLI:  ``PYTHONPATH=src python -m repro.serve --demo 96``
"""
from .cache import CacheStats, ProgramCache
from .queue import (PendingRun, QuarantinedError, QueueFullError, SpecError,
                    SubmissionQueue, parse_runspec)
from .scheduler import Batch, CoalescingScheduler
from .service import CertificationService, ResultEnvelope, replay_trace
from .workload import Arrival, DEFAULT_STRUCTURES, spec_pool, synthetic_trace

__all__ = [
    "Arrival", "Batch", "CacheStats", "CertificationService",
    "CoalescingScheduler", "DEFAULT_STRUCTURES", "PendingRun",
    "ProgramCache", "QuarantinedError", "QueueFullError", "ResultEnvelope",
    "SpecError", "SubmissionQueue", "parse_runspec", "replay_trace",
    "spec_pool", "synthetic_trace",
]
