"""Synthetic heavy-traffic workloads for the certification service.

A trace is a deterministic list of ``Arrival``s: timestamp, client id,
RunSpec.  Specs are drawn from a small pool per *structure* — an
(algorithm, channel) pair over one instance shape, i.e. one
``group_key`` once planned — so a trace exercises exactly the mix a
continuous-batching scheduler is built for: many concurrent clients,
few distinct compiled programs, arbitrary interleaving.  Everything is
seeded; the same (seed, sizes) produce the same trace byte-for-byte,
which is what lets ``tests/test_serve.py`` assert exact cache counters
and ``benchmarks/serve_throughput.py`` gate the hit-rate floor.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Sequence, Tuple

from .. import api


# (algorithm, channel): each pair traces to a distinct group_key (the
# channel both changes the upload graph and is an explicit key axis)
DEFAULT_STRUCTURES: Tuple[Tuple[str, str], ...] = (
    ("dagd", "identity"),
    ("dgd", "identity"),
    ("dagd", "fp16"),
)


@dataclasses.dataclass(frozen=True)
class Arrival:
    t: float
    client_id: str
    spec: api.RunSpec


def spec_pool(structures: Sequence[Tuple[str, str]] = DEFAULT_STRUCTURES,
              kappas: Sequence[float] = (8.0, 16.0, 32.0, 64.0),
              d: int = 12, m: int = 2, rounds: int = 30,
              eps: Tuple[float, ...] = (1e-2,)) -> List[List[api.RunSpec]]:
    """One list of distinct specs per structure: same shape/budget (one
    group key), different data (the kappa grid)."""
    return [[api.RunSpec(
        instance="thm2_chain",
        instance_params=dict(d=d, kappa=float(k), lam=0.5, m=m),
        algorithm=algo, rounds=rounds, eps=eps, channel=channel,
        tag=f"serve-{algo}-{channel}")
        for k in kappas]
        for algo, channel in structures]


def synthetic_trace(n_per_structure: int = 64, seed: int = 0,
                    dt: float = 1e-3, clients: int = 4,
                    pools: Sequence[Sequence[api.RunSpec]] = None,
                    **pool_kwargs) -> List[Arrival]:
    """A dense shuffled trace: ``n_per_structure`` arrivals per
    structure, inter-arrival ``dt``, clients assigned round-robin after
    the shuffle so every client's stream mixes structures."""
    if pools is None:
        pools = spec_pool(**pool_kwargs)
    specs: List[api.RunSpec] = []
    for pool in pools:
        specs.extend(pool[i % len(pool)] for i in range(n_per_structure))
    rng = random.Random(seed)
    rng.shuffle(specs)
    return [Arrival(t=i * dt, client_id=f"c{i % clients}", spec=spec)
            for i, spec in enumerate(specs)]
