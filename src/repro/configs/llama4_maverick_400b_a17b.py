"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert), vocab=202048, MoE 128 experts top-1, alternating
dense/MoE layers (interleave step 2), early fusion (text tokens exercised;
vision tower out of scope per the frontend carve-out). FSDP overlay
required (~400B params). [hf:meta-llama/Llama-4-Scout-17B-16E]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.moe import MoEConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "moe"
FSDP = True


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        vocab=202048, d_model=5120, n_layers=48,
        pattern=(LayerSpec("attn", "dense"), LayerSpec("attn", "moe")),
        attn=attn(5120, 40, 8, 128),
        mlp=MLPConfig(d_model=5120, d_ff=16384, activation="swiglu"),
        moe=MoEConfig(d_model=5120, d_ff=8192, n_experts=128, top_k=1),
        norm="rmsnorm",
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("attn", "dense"), LayerSpec("attn", "moe")),
        attn=attn(128, 4, 2, 32, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="swiglu"),
        moe=MoEConfig(d_model=128, d_ff=64, n_experts=4, top_k=1),
        norm="rmsnorm", remat="none", dtype=jnp.float32,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
