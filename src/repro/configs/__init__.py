"""Assigned architecture configs (+ the paper's own ERM configs).

Each module exposes:
    full()    -> ModelConfig / EncDecConfig with the exact assigned spec
    smoke()   -> reduced same-family variant (<=2 layers, d_model<=512,
                 <=4 experts) for CPU tests
    input_specs(shape_name, mesh_kind) -> ShapeDtypeStruct stand-ins
    SUPPORTED_SHAPES -> which of the 4 input shapes apply (long_500k only
                 for sub-quadratic archs; see DESIGN.md)

Registry: ``get(arch_id)``.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "granite_moe_1b_a400m",
    "whisper_large_v3",
    "jamba_1_5_large_398b",
    "mamba2_780m",
    "qwen1_5_32b",
    "stablelm_12b",
    "paligemma_3b",
    "gemma3_27b",
    "starcoder2_15b",
    "llama4_maverick_400b_a17b",
]

# canonical ids as assigned (dash form) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-780m": "mamba2_780m",
    "qwen1.5-32b": "qwen1_5_32b",
    "stablelm-12b": "stablelm_12b",
    "paligemma-3b": "paligemma_3b",
    "gemma3-27b": "gemma3_27b",
    "starcoder2-15b": "starcoder2_15b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
})


def get(arch_id: str):
    mod = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}")


def canonical_ids():
    return [a.replace("_", "-") for a in ARCHS]
