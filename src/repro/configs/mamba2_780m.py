"""mamba2-780m [ssm] — 48L d_model=1536, attention-free, ssm_state=128,
SSD (state-space duality). d_inner = 2*d_model = 3072, head_dim 64 ->
48 SSD heads. long_500k RUNS (O(1) state cache). [arXiv:2405.21060]

Arch-applicability note (DESIGN.md): the paper's span-rule round bounds
govern convex ERM, not recurrent scans; only the feature-partition
communication model transfers (state heads sharded on `model`, scan needs
no collectives).
"""
import jax.numpy as jnp

from ..models.mamba2 import Mamba2Config
from ..models.transformer import LayerSpec, ModelConfig
from ._common import lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
FAMILY = "ssm"


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        vocab=50280, d_model=1536, n_layers=48,
        pattern=(LayerSpec("mamba", "none"),),
        mamba=Mamba2Config(d_model=1536, n_heads=48, head_dim=64,
                           d_state=128, n_groups=1, chunk=256),
        norm="rmsnorm",
        citation="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("mamba", "none"),),
        mamba=Mamba2Config(d_model=128, n_heads=4, head_dim=32,
                           d_state=16, n_groups=1, chunk=32),
        norm="rmsnorm", remat="none", dtype=jnp.float32,
        citation="arXiv:2405.21060",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
