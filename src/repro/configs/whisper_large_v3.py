"""whisper-large-v3 [audio] — enc-dec, 32+32L d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866. Conv/mel frontend is a STUB per the carve-out:
input_specs provides precomputed frame embeddings (B, 1500, D).

Shape adaptations (DESIGN.md): the real decoder ctx is 448; train_4k splits
the 4k token budget into enc frames + dec tokens (dec len <= max_target), and
decode_32k exercises a longform 32768-entry self-KV ring (positions clamp
to the learned table). long_500k SKIPPED (full attention, enc-dec).
[arXiv:2212.04356]
"""
import jax
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.encdec import EncDecConfig, init_cache
from ._common import attn
from . import shapes as S

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "audio"
N_FRAMES = 1500


def full() -> EncDecConfig:
    return EncDecConfig(
        name="whisper-large-v3",
        vocab=51866, d_model=1280,
        n_enc_layers=32, n_dec_layers=32,
        attn=attn(1280, 20, 20, 64, rope_base=0.0),
        mlp=MLPConfig(d_model=1280, d_ff=5120, activation="gelu"),
        n_frames=N_FRAMES, max_target=4096,
        citation="arXiv:2212.04356",
    )


def smoke() -> EncDecConfig:
    return EncDecConfig(
        name="whisper-smoke",
        vocab=512, d_model=128,
        n_enc_layers=2, n_dec_layers=2,
        attn=attn(128, 4, 4, 32, rope_base=0.0, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="gelu"),
        n_frames=64, max_target=128, remat="none", dtype=jnp.float32,
        citation="arXiv:2212.04356",
    )


def input_specs(shape_name: str, cfg: EncDecConfig | None = None):
    cfg = cfg or full()
    shape = S.SHAPES[shape_name]
    b = shape.global_batch
    if shape.kind == "train":
        s_dec = min(shape.seq_len - cfg.n_frames, cfg.max_target) \
            if shape.seq_len > cfg.n_frames else 448
        return {
            "frames": jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                           cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((b, s_dec), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s_dec), jnp.int32),
        }
    if shape.kind == "prefill":
        # decoder teacher-forced pass of seq_len against encoder states
        return {
            "frames": jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                           cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": init_cache(cfg, b, shape.seq_len, abstract=True),
    }
