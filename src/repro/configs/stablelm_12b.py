"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "dense"


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        vocab=100352, d_model=5120, n_layers=40,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(5120, 32, 8, 160),
        mlp=MLPConfig(d_model=5120, d_ff=13824, activation="swiglu"),
        norm="layernorm",
        citation="hf:stabilityai/stablelm-2-1_6b",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(128, 4, 2, 32, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="swiglu"),
        norm="layernorm", remat="none", dtype=jnp.float32,
        citation="hf:stabilityai/stablelm-2-1_6b",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
