"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "dense"


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        vocab=152064, d_model=5120, n_layers=64,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(5120, 40, 40, 128, qkv_bias=True),
        mlp=MLPConfig(d_model=5120, d_ff=27392, activation="swiglu"),
        norm="rmsnorm",
        citation="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(128, 4, 4, 32, qkv_bias=True, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="swiglu"),
        norm="rmsnorm", remat="none", dtype=jnp.float32,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
