"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
import jax.numpy as jnp

from ..models.moe import MoEConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "moe"


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        vocab=49155, d_model=1024, n_layers=24,
        pattern=(LayerSpec("attn", "moe"),),
        attn=attn(1024, 16, 8, 64),
        moe=MoEConfig(d_model=1024, d_ff=512, n_experts=32, top_k=8),
        norm="rmsnorm",
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("attn", "moe"),),
        attn=attn(128, 4, 2, 32, q_chunk=64),
        moe=MoEConfig(d_model=128, d_ff=64, n_experts=4, top_k=2),
        norm="rmsnorm", remat="none", dtype=jnp.float32,
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
