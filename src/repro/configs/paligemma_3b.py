"""paligemma-3b [vlm] — 18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384
vocab=257216. SigLIP vision tower is a STUB per the carve-out:
input_specs provides 256 precomputed patch embeddings (B, 256, D);
prefix-LM masking (bidirectional prefix over patches). [arXiv:2407.07726]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "vlm"
N_PATCHES = 256


def full() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        vocab=257216, d_model=2048, n_layers=18,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(2048, 8, 1, 256),
        mlp=MLPConfig(d_model=2048, d_ff=16384, activation="swiglu"),
        norm="rmsnorm", scale_embed=True,
        prefix_lm=True, n_prefix=N_PATCHES,
        citation="arXiv:2407.07726",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(128, 4, 1, 32, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="swiglu"),
        norm="rmsnorm", scale_embed=True,
        prefix_lm=True, n_prefix=16, remat="none", dtype=jnp.float32,
        citation="arXiv:2407.07726",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    cfg = cfg or full()
    return lm_input_specs(cfg, shape_name, n_prefix=cfg.n_prefix)
