"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2, Mamba+attention 1:7 interleave.
72 = 9 repeats of an 8-layer period [attn, mamba x7]; MoE on every other
layer (odd positions). FSDP sharding overlay required (398B params).
long_500k RUNS (hybrid: mamba state + windowless attn on 1/8 layers whose
KV cache at 524288 x kv8 x dh128 x 9 layers is shardable).
[arXiv:2403.19887]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.moe import MoEConfig
from ..models.mamba2 import Mamba2Config
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
FAMILY = "hybrid"
FSDP = True


def _pattern():
    specs = []
    for pos in range(8):
        kind = "attn" if pos == 0 else "mamba"
        ffn = "moe" if pos % 2 == 1 else "dense"
        specs.append(LayerSpec(kind, ffn))
    return tuple(specs)


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        vocab=65536, d_model=8192, n_layers=72,
        pattern=_pattern(),
        attn=attn(8192, 64, 8, 128, rope_base=0.0),  # jamba: no RoPE
        mlp=MLPConfig(d_model=8192, d_ff=24576, activation="swiglu"),
        moe=MoEConfig(d_model=8192, d_ff=24576, n_experts=16, top_k=2),
        mamba=Mamba2Config(d_model=8192, n_heads=128, head_dim=128,
                           d_state=128, n_groups=8, chunk=256),
        norm="rmsnorm",
        citation="arXiv:2403.19887",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        vocab=512, d_model=128, n_layers=4,
        pattern=(LayerSpec("attn", "dense"), LayerSpec("mamba", "moe"),
                 LayerSpec("mamba", "dense"), LayerSpec("mamba", "moe")),
        attn=attn(128, 4, 2, 32, rope_base=0.0, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="swiglu"),
        moe=MoEConfig(d_model=128, d_ff=64, n_experts=4, top_k=2),
        mamba=Mamba2Config(d_model=128, n_heads=4, head_dim=32,
                           d_state=16, n_groups=2, chunk=32),
        norm="rmsnorm", remat="none", dtype=jnp.float32,
        citation="arXiv:2403.19887",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
