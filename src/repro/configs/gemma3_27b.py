"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global attention (sliding window 1024), 128k ctx.
long_500k RUNS for this arch: decode against the window cache is O(W) on
the 5/6 local layers. [hf:google/gemma-3-1b-pt]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
FAMILY = "dense"
LOCAL_WINDOW = 1024


def full() -> ModelConfig:
    local = LayerSpec("attn", "dense", window=LOCAL_WINDOW)
    glob = LayerSpec("attn", "dense", window=None)
    return ModelConfig(
        name="gemma3-27b",
        vocab=262144, d_model=5376, n_layers=62,
        # 5 local : 1 global; 62 = 10*6 + 2 remainder local layers
        pattern=(local, local, local, local, local, glob),
        attn=attn(5376, 32, 16, 128),
        mlp=MLPConfig(d_model=5376, d_ff=21504, activation="swiglu"),
        norm="rmsnorm", scale_embed=True,
        citation="hf:google/gemma-3-1b-pt",
    )


def smoke() -> ModelConfig:
    local = LayerSpec("attn", "dense", window=64)
    glob = LayerSpec("attn", "dense", window=None)
    return ModelConfig(
        name="gemma3-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(local, glob),
        attn=attn(128, 4, 2, 32, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="swiglu"),
        norm="rmsnorm", scale_embed=True, remat="none", dtype=jnp.float32,
        citation="hf:google/gemma-3-1b-pt",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
