"""Shared builders for the architecture config modules."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.layers import AttnConfig
from ..models.transformer import LayerSpec, ModelConfig, init_cache
from . import shapes as S


def dense_pattern(window_pattern: Tuple[Optional[int], ...] = (None,),
                  ffn: str = "dense") -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec("attn", ffn, w) for w in window_pattern)


def attn(d_model, n_heads, n_kv_heads, head_dim, qkv_bias=False,
         rope_base=10000.0, q_chunk=1024):
    return AttnConfig(d_model=d_model, n_heads=n_heads,
                      n_kv_heads=n_kv_heads, head_dim=head_dim,
                      qkv_bias=qkv_bias, rope_base=rope_base,
                      q_chunk=q_chunk)


def lm_input_specs(cfg: ModelConfig, shape_name: str,
                   n_prefix: int = 0):
    """ShapeDtypeStruct stand-ins for decoder-only LM steps."""
    shape = S.SHAPES[shape_name]
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        s_text = shape.seq_len - n_prefix
        out = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
        if n_prefix:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, n_prefix, cfg.d_model), cfg.dtype)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        return out
    # decode: one token + cache of seq_len
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": init_cache(cfg, b, shape.seq_len, abstract=True),
    }
