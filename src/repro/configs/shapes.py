"""The four assigned input shapes + ShapeDtypeStruct builders.

Decode shapes lower ``serve_step`` (ONE token against a seq_len cache);
train/prefill lower ``train_step`` / prefill forward.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def token_specs(shape: InputShape, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if with_labels:
        return {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s),
                                                              jnp.int32)}
    return {"tokens": tok}


def decode_token_spec(shape: InputShape):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
