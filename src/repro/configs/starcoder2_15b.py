"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE. [arXiv:2402.19173]
"""
import jax.numpy as jnp

from ..models.layers import MLPConfig
from ..models.transformer import LayerSpec, ModelConfig
from ._common import attn, lm_input_specs

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
FAMILY = "dense"


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        vocab=49152, d_model=6144, n_layers=40,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(6144, 48, 4, 128),
        mlp=MLPConfig(d_model=6144, d_ff=24576, activation="gelu"),
        norm="layernorm",
        citation="arXiv:2402.19173",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        vocab=512, d_model=128, n_layers=2,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(128, 4, 2, 32, q_chunk=64),
        mlp=MLPConfig(d_model=128, d_ff=256, activation="gelu"),
        norm="layernorm", remat="none", dtype=jnp.float32,
        citation="arXiv:2402.19173",
    )


def input_specs(shape_name: str, cfg: ModelConfig | None = None):
    return lm_input_specs(cfg or full(), shape_name)
