from .pipeline import (TokenDataConfig, synthetic_lm_batches,
                       synthetic_erm_shards, frame_stub, patch_stub)

__all__ = ["TokenDataConfig", "synthetic_lm_batches",
           "synthetic_erm_shards", "frame_stub", "patch_stub"]
