"""Data pipeline: deterministic synthetic streams for LM training, the
modality stubs (audio frames / vision patches per the carve-out), and
column-sharded ERM data placement for the core algorithms.

The LM stream is a reproducible Zipf-ish token source with a simple
Markov structure so the loss actually decreases during the examples'
short training runs (pure-uniform tokens would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0


def synthetic_lm_batches(cfg: TokenDataConfig) -> Iterator[dict]:
    """Infinite iterator of {tokens, labels} with learnable bigram structure."""
    rng = np.random.RandomState(cfg.seed)
    v = cfg.vocab
    # sparse deterministic bigram table + noise
    succ = rng.randint(0, v, size=(v,))
    while True:
        first = rng.randint(0, v, size=(cfg.batch, 1))
        seq = [first]
        cur = first
        for _ in range(cfg.seq_len):
            nxt = np.where(rng.rand(cfg.batch, 1) < 0.8, succ[cur],
                           rng.randint(0, v, size=(cfg.batch, 1)))
            seq.append(nxt)
            cur = nxt
        arr = np.concatenate(seq, axis=1)
        yield {"tokens": jnp.asarray(arr[:, :-1], jnp.int32),
               "labels": jnp.asarray(arr[:, 1:], jnp.int32)}


def frame_stub(batch: int, n_frames: int, d_model: int, seed: int = 0,
               dtype=jnp.bfloat16):
    """Precomputed audio-frame embeddings (mel+conv frontend carve-out)."""
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (batch, n_frames, d_model)).astype(dtype)


def patch_stub(batch: int, n_patches: int, d_model: int, seed: int = 0,
               dtype=jnp.bfloat16):
    """Precomputed image-patch embeddings (SigLIP frontend carve-out)."""
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (batch, n_patches, d_model)).astype(dtype)


def synthetic_erm_shards(n: int, d: int, m: int, seed: int = 0):
    """Column-sharded synthetic ERM data: returns (shards list, full A, y)."""
    from ..core.erm import make_random_erm
    from ..core.partition import even_partition
    prob = make_random_erm(n=n, d=d, seed=seed)
    part = even_partition(d, m)
    return part.split_columns(prob.A), prob
