"""Pallas TPU kernel: MoE top-k weighted combine (beyond-paper hot spot).

After expert computation, each token's k expert outputs are combined with
router weights:  y[t] = sum_k w[t,k] * x[t,k,:].  Done naively this is k
separate HBM passes; the kernel fuses them into one pass with the token
dimension tiled into VMEM blocks (k is small and unrolled).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 256
BLOCK_D = 512


def _combine_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]                 # (BT, k, BD)
    w = w_ref[...]                 # (BT, k)
    acc = jnp.zeros(o_ref.shape, o_ref.dtype)
    for kk in range(x.shape[1]):   # k is a small static constant: unroll
        acc += x[:, kk, :] * w[:, kk][:, None].astype(o_ref.dtype)
    o_ref[...] = acc


def moe_combine(expert_out, combine_w, *, block_t: int = BLOCK_T,
                block_d: int = BLOCK_D, interpret: bool | None = None):
    """expert_out: (T, k, D); combine_w: (T, k) -> (T, D)."""
    t, k, d = expert_out.shape
    bt = min(block_t, _rup(t, 8))
    bd = min(block_d, _rup(d, 128))
    pt, pd = (-t) % bt, (-d) % bd
    x = jnp.pad(expert_out, ((0, pt), (0, 0), (0, pd)))
    w = jnp.pad(combine_w, ((0, pt), (0, 0)))
    grid = (x.shape[0] // bt, x.shape[2] // bd)
    out = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, k, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bt, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], x.shape[2]),
                                       expert_out.dtype),
        interpret=(jax.default_backend() != "tpu" if interpret is None
                   else interpret),
    )(x, w)
    return out[:t, :d]


def _rup(x: int, to: int) -> int:
    return max(to, (x + to - 1) // to * to)
