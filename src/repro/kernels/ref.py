"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax.numpy as jnp


def feature_matvec_ref(A_j, w_j):
    """z_j = A_j w_j — machine j's summand of the response ReduceAll.

    A_j: (n, d_j), w_j: (d_j,) -> (n,)
    """
    return (A_j @ w_j[:, None])[:, 0]


def feature_rmatvec_ref(A_j, r):
    """g_j = A_j^T r — the partial-gradient data term.

    A_j: (n, d_j), r: (n,) -> (d_j,)
    """
    return (A_j.T @ r[:, None])[:, 0]


def feature_hvp_ref(A_j, h, av):
    """u_j = A_j^T (h ⊙ av) — the HVP data term given reduced av = Av.

    A_j: (n, d_j), h: (n,), av: (n,) or (n, B) -> (d_j,) or (d_j, B)
    """
    if av.ndim == 1:
        return (A_j.T @ (h * av)[:, None])[:, 0]
    return A_j.T @ (h[:, None] * av)


def fused_pgrad_ref(A_j, r, w_j, mask_j, *, n, lam):
    """g_j = (A_j^T r / n + lam w_j) * mask_j — the gradient epilogue
    applied to the reduction, matching ``fused_round.fused_pgrad``.

    A_j: (n_rows, d_j); r: (n_rows,) or (n_rows, B); w_j like the
    output; mask_j: (d_j,).
    """
    if r.ndim == 1:
        g = feature_rmatvec_ref(A_j, r)
        return (g / n + lam * w_j) * mask_j
    g = A_j.T @ r
    return (g / n + lam * w_j) * mask_j[:, None]


def fused_phvp_ref(A_j, h, av, v_j, mask_j, *, n, lam):
    """u_j = (A_j^T (h ⊙ av) / n + lam v_j) * mask_j — the HVP epilogue
    applied to the reduction, matching ``fused_round.fused_phvp``."""
    out = feature_hvp_ref(A_j, h, av)
    mk = mask_j if av.ndim == 1 else mask_j[:, None]
    return (out / n + lam * v_j) * mk


def tridiag_matvec_ref(diag, off, v):
    """Banded tridiagonal matvec: out = T v with T = tri(off, diag, off).

    diag: (d,), off: (d-1,), v: (d,) -> (d,)
    """
    out = diag * v
    out = out.at[:-1].add(off * v[1:])
    out = out.at[1:].add(off * v[:-1])
    return out


def moe_combine_ref(expert_out, combine_w):
    """Weighted combine of expert outputs back to token order.

    expert_out: (T, k, D) per-token top-k expert outputs,
    combine_w : (T, k) router weights -> (T, D)
    """
    return jnp.einsum("tkd,tk->td", expert_out, combine_w)


def flash_decode_ref(q, k, v, bias):
    """One-token attention vs a cached KV with additive mask bias.

    q: (B, Hk, G, Dh); k/v: (B, T, Hk, Dh); bias: (B, T) -> (B, Hk, G, Dh)
    """
    import jax
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgt,bthd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
