"""Pallas TPU kernels for the feature-partitioned ERM hot loop.

Every algorithm in the paper's family F^{lam,L} spends its FLOPs in two
GEMVs per round on each machine:

    z_j = A_j w_j        (n x d_j) @ (d_j)   -> the ReduceAll summand
    g_j = A_j^T r        (d_j x n) @ (n)     -> the partial-gradient term

On TPU these are tall-skinny matmuls; the kernels below tile them into
MXU-aligned (multiples of 128) VMEM blocks with an accumulation grid.
The contraction dimension is the innermost grid axis, so each output
block stays resident in VMEM while partial products accumulate into it
(revisiting semantics), and HBM traffic is one pass over A_j.

Batched right-hand sides are supported (w: (d_j, B), r: (n, B)) because
DISCO-F's CG and the benchmark harness evaluate multiple vectors at once;
B=1 recovers the GEMV. The batch axis is tiled into BLOCK_B-wide VMEM
blocks of its own (a third grid axis), so a wide RHS panel (B > 128)
never forces the whole panel into one block.

``feature_hvp`` is the fused Hessian-vector-product data term: machine j
needs A_j^T (h ⊙ av) where h = l''(z) and av = Av are shared R^n vectors.
Fusing the Hadamard into the reduction pass keeps the scaled residual
block VMEM-resident instead of materializing h ⊙ av in HBM first.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Block sizes: MXU-aligned. A-block of 512x512 f32 = 1 MiB in VMEM; with
# double buffering this uses ~2-3 MiB of the ~16 MiB/core budget.
BLOCK_N = 512
BLOCK_D = 512
BLOCK_B = 128


def _matvec_kernel(a_ref, w_ref, o_ref):
    """Grid (n_blocks, b_blocks, d_blocks): o[i,b] += A[i,j] @ w[j,b];
    the contraction axis j is innermost so o stays VMEM-resident."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                          preferred_element_type=o_ref.dtype)


def feature_matvec(A_j, w_j, *, block_n: int = BLOCK_N,
                   block_d: int = BLOCK_D, block_b: int = BLOCK_B,
                   interpret: bool | None = None):
    """z_j = A_j @ w_j.  A_j: (n, d_j); w_j: (d_j,) or (d_j, B)."""
    squeeze = w_j.ndim == 1
    if squeeze:
        w_j = w_j[:, None]
    n, dj = A_j.shape
    b = w_j.shape[1]
    bn, bd = min(block_n, _rup(n)), min(block_d, _rup(dj))
    bb = min(block_b, _rup(b))
    A_p = _pad2(A_j, bn, bd)
    w_p = _pad2(w_j, bd, bb)
    grid = (A_p.shape[0] // bn, w_p.shape[1] // bb, A_p.shape[1] // bd)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, k, j: (i, j)),
            pl.BlockSpec((bd, bb), lambda i, k, j: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bb), lambda i, k, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((A_p.shape[0], w_p.shape[1]),
                                       _acc_dtype(A_j.dtype)),
        interpret=_interp(interpret),
    )(A_p, w_p)
    out = out[:n, :b].astype(A_j.dtype)
    return out[:, 0] if squeeze else out


def _rmatvec_kernel(a_ref, r_ref, o_ref):
    """Grid (d_blocks, b_blocks, n_blocks): o[j,b] += A[i,j]^T @ r[i,b];
    the contraction axis i is innermost so o stays VMEM-resident."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...].T, r_ref[...],
                          preferred_element_type=o_ref.dtype)


def feature_rmatvec(A_j, r, *, block_n: int = BLOCK_N,
                    block_d: int = BLOCK_D, block_b: int = BLOCK_B,
                    interpret: bool | None = None):
    """g_j = A_j^T @ r.  A_j: (n, d_j); r: (n,) or (n, B)."""
    squeeze = r.ndim == 1
    if squeeze:
        r = r[:, None]
    n, dj = A_j.shape
    b = r.shape[1]
    bn, bd = min(block_n, _rup(n)), min(block_d, _rup(dj))
    bb = min(block_b, _rup(b))
    A_p = _pad2(A_j, bn, bd)
    r_p = _pad2(r, bn, bb)
    grid = (A_p.shape[1] // bd, r_p.shape[1] // bb, A_p.shape[0] // bn)
    out = pl.pallas_call(
        _rmatvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, k, i: (i, j)),
            pl.BlockSpec((bn, bb), lambda j, k, i: (i, k)),
        ],
        out_specs=pl.BlockSpec((bd, bb), lambda j, k, i: (j, k)),
        out_shape=jax.ShapeDtypeStruct((A_p.shape[1], r_p.shape[1]),
                                       _acc_dtype(A_j.dtype)),
        interpret=_interp(interpret),
    )(A_p, r_p)
    out = out[:dj, :b].astype(A_j.dtype)
    return out[:, 0] if squeeze else out


def _hvp_kernel(a_ref, h_ref, r_ref, o_ref):
    """Grid (d_blocks, b_blocks, n_blocks): o[j,b] += A[i,j]^T (h[i] ⊙
    r[i,b]); the Hadamard happens on the VMEM-resident r block, so the
    scaled residual never round-trips through HBM."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...].T, h_ref[...] * r_ref[...],
                          preferred_element_type=o_ref.dtype)


def feature_hvp(A_j, h, av, *, block_n: int = BLOCK_N,
                block_d: int = BLOCK_D, block_b: int = BLOCK_B,
                interpret: bool | None = None):
    """u_j = A_j^T (h ⊙ av) — the HVP data term in one fused pass.

    A_j: (n, d_j); h: (n,) per-sample curvature l''(z); av: (n,) or
    (n, B) reduced Av right-hand side(s).
    """
    squeeze = av.ndim == 1
    if squeeze:
        av = av[:, None]
    n, dj = A_j.shape
    b = av.shape[1]
    bn, bd = min(block_n, _rup(n)), min(block_d, _rup(dj))
    bb = min(block_b, _rup(b))
    A_p = _pad2(A_j, bn, bd)
    h_p = _pad2(h[:, None], bn, 1)
    r_p = _pad2(av, bn, bb)
    grid = (A_p.shape[1] // bd, r_p.shape[1] // bb, A_p.shape[0] // bn)
    out = pl.pallas_call(
        _hvp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, k, i: (i, j)),
            pl.BlockSpec((bn, 1), lambda j, k, i: (i, 0)),
            pl.BlockSpec((bn, bb), lambda j, k, i: (i, k)),
        ],
        out_specs=pl.BlockSpec((bd, bb), lambda j, k, i: (j, k)),
        out_shape=jax.ShapeDtypeStruct((A_p.shape[1], r_p.shape[1]),
                                       _acc_dtype(A_j.dtype)),
        interpret=_interp(interpret),
    )(A_p, h_p.astype(A_j.dtype), r_p)
    out = out[:dj, :b].astype(A_j.dtype)
    return out[:, 0] if squeeze else out


# ---- helpers ---------------------------------------------------------------

def _rup(x: int, to: int = 128) -> int:
    return max(to, (x + to - 1) // to * to)


def _pad2(x, r0: int, r1: int):
    p0 = (-x.shape[0]) % r0
    p1 = (-x.shape[1]) % r1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _acc_dtype(dt):
    return jnp.float32 if dt in (jnp.bfloat16, jnp.float16,
                                 jnp.dtype("bfloat16"),
                                 jnp.dtype("float16")) else dt


def _interp(flag):
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"
