"""Pallas TPU kernels for the perf-critical compute layers.

feature_matvec / feature_rmatvec : the ERM hot loop of every algorithm in
    the paper's family (A_j w_j and A_j^T r per round, per machine).
feature_hvp    : fused HVP data term A_j^T (h ⊙ av) for DISCO-F's CG.
tridiag_matvec : hard-instance Hessian apply (banded, one-VMEM-pass).
moe_combine    : top-k expert-output combine (beyond-paper hot spot).

Import surface: ``from repro.kernels import ops`` (jit'd wrappers with a
``use_kernel=False`` escape hatch to the pure-jnp oracles in ``ref.py``).
Kernels are validated on CPU with interpret=True (tests/test_kernels.py);
TPU is the compile target.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
