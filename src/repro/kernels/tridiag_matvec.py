"""Pallas TPU kernel: banded tridiagonal matvec (hard-instance Hessian op).

The paper's hard function has Hessian  H = c*A + lam*I  with A tridiagonal;
every oracle call in the lower-bound experiments (gradients, HVPs, CG) is
dominated by  T @ v  with T given by bands (diag, off). Dense H would be
O(d^2) HBM traffic; the banded kernel is one O(d) VMEM pass fusing the
three FMA streams.

Layout: the logical (d,) vectors are reshaped to (rows, 128) and tiled in
(block_rows, 128) VMEM blocks. Halo exchange across the row dimension is
done by binding the SAME input array to three BlockSpecs whose index maps
point at the previous / current / next block (clamped at the boundary);
the off-band coefficient arrays are pre-masked so the clamped duplicates
contribute zero at the edges.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 8


def _tridiag_kernel(diag_ref, lo_ref, hi_ref, vprev_ref, vcur_ref,
                    vnext_ref, o_ref):
    """out = diag*v + hi*shift_up(v) + lo*shift_down(v), with halos.

    Blocks are (R, 128) row-major windows of the length-d vector, so the
    "next element" of position (r, 127) is (r+1, 0); shift across the
    block boundary pulls one element from the neighbour block.
    """
    v = vcur_ref[...]
    r, lanes = v.shape
    flat = v.reshape(1, r * lanes)
    nxt_first = vnext_ref[0, 0]
    prv_last = vprev_ref[r - 1, lanes - 1]
    up = jnp.concatenate(
        [flat[:, 1:], jnp.full((1, 1), nxt_first, v.dtype)], axis=1
    ).reshape(r, lanes)
    down = jnp.concatenate(
        [jnp.full((1, 1), prv_last, v.dtype), flat[:, :-1]], axis=1
    ).reshape(r, lanes)
    o_ref[...] = diag_ref[...] * v + hi_ref[...] * up + lo_ref[...] * down


def tridiag_matvec(diag, off, v, *, block_rows: int = BLOCK_ROWS,
                   interpret: bool | None = None):
    """T @ v for tridiagonal T with main diagonal ``diag`` (d,) and
    symmetric off-diagonal ``off`` (d-1,)."""
    d = v.shape[0]
    # coefficient of v[k+1] at row k, zero at k = d-1 (and in padding)
    hi = jnp.concatenate([off, jnp.zeros((1,), v.dtype)])
    # coefficient of v[k-1] at row k, zero at k = 0
    lo = jnp.concatenate([jnp.zeros((1,), v.dtype), off])

    rows = max(block_rows, -(-d // LANE))
    rows = -(-rows // block_rows) * block_rows
    total = rows * LANE

    def _prep(x):
        return jnp.pad(x, (0, total - d)).reshape(rows, LANE)

    diag2, lo2, hi2, v2 = _prep(diag), _prep(lo), _prep(hi), _prep(v)
    nblk = rows // block_rows
    spec_cur = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    spec_prev = pl.BlockSpec((block_rows, LANE),
                             lambda i: (jnp.maximum(i - 1, 0), 0))
    spec_next = pl.BlockSpec((block_rows, LANE),
                             lambda i: (jnp.minimum(i + 1, nblk - 1), 0))
    out = pl.pallas_call(
        _tridiag_kernel,
        grid=(nblk,),
        in_specs=[spec_cur, spec_cur, spec_cur, spec_prev, spec_cur,
                  spec_next],
        out_specs=spec_cur,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), v.dtype),
        interpret=(jax.default_backend() != "tpu" if interpret is None
                   else interpret),
    )(diag2, lo2, hi2, v2, v2, v2)
    return out.reshape(-1)[:d]
