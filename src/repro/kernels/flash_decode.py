"""Pallas TPU kernel: flash-decode — one-token attention against a long
KV cache with online softmax, streaming KV blocks through VMEM.

This is the serving hot spot the roofline exposed (decode_32k/long_500k
are KV-bandwidth-bound): the naive path materializes (B, Hk, G, T) logits
in HBM; this kernel keeps a (G, BLOCK_T) tile in VMEM, carries the
running (max, denom, weighted-sum) online-softmax state in scratch, and
writes only the (G, Dh) output — one HBM pass over K/V, nothing else.

Layout: grid (B, Hk, T/BLOCK_T) with the KV-block axis innermost, so the
scratch state lives across the streaming axis. Masking (causal validity,
ring-buffer holes, sliding windows) is supplied by the caller as an
additive f32 bias (B, T) — the kernel itself is mask-agnostic.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
BLOCK_T = 512


def _flash_decode_kernel(q_ref, k_ref, v_ref, b_ref, o_ref,
                         m_ref, l_ref, acc_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(F32)                   # (G, Dh)
    k = k_ref[0, :, 0, :].astype(F32)             # (BT, Dh)
    v = v_ref[0, :, 0, :].astype(F32)             # (BT, Dh)
    bias = b_ref[0].astype(F32)                   # (BT,)
    scale = q.shape[-1] ** -0.5

    s = jnp.dot(q, k.T, preferred_element_type=F32) * scale  # (G, BT)
    s = s + bias[None, :]

    m_prev = m_ref[...]                           # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                        # (G, BT)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + \
        jnp.dot(p, v, preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(t == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k, v, bias, *, block_t: int = BLOCK_T,
                 interpret: bool | None = None):
    """q: (B, Hk, G, Dh); k/v: (B, T, Hk, Dh); bias: (B, T) additive f32.
    Returns (B, Hk, G, Dh)."""
    b, hk, g, dh = q.shape
    t = k.shape[1]
    bt = min(block_t, t)
    if t % bt:
        pad = bt - t % bt
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)),
                       constant_values=-1e30)
        t = t + pad
    grid = (b, hk, t // bt)
    return pl.pallas_call(
        _flash_decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ti: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bt, 1, dh), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, bt, 1, dh), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, bt), lambda bi, hi, ti: (bi, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ti:
                               (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), F32),   # running max
            pltpu.VMEM((g, 1), F32),   # running denom
            pltpu.VMEM((g, dh), F32),  # running weighted sum
        ],
        interpret=(jax.default_backend() != "tpu" if interpret is None
                   else interpret),
    )(q, k, v, bias)
