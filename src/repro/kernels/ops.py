"""Public jit'd wrappers for the Pallas kernels.

These are what the rest of the framework imports. Each op dispatches to
the Pallas kernel (compiled for TPU; interpret-mode on CPU) and carries a
``use_kernel=False`` escape hatch that routes to the pure-jnp oracle in
``ref.py`` — the escape hatch is also how the big-model dry-run lowers on
the 512-device CPU mesh (interpret-mode Pallas inside pjit would be
pathologically slow to trace there).
"""
from __future__ import annotations

import functools

import jax

from . import ref
from .feature_matvec import feature_matvec as _fmv, \
    feature_rmatvec as _frmv, feature_hvp as _fhvp
from .fused_round import fused_pgrad as _fpg, fused_phvp as _fph
from .tridiag_matvec import tridiag_matvec as _tdmv
from .moe_combine import moe_combine as _moec
from .flash_decode import flash_decode as _fdec


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def feature_matvec(A_j, w_j, use_kernel: bool = True):
    """z_j = A_j @ w_j (the response summand)."""
    if use_kernel:
        return _fmv(A_j, w_j)
    return ref.feature_matvec_ref(A_j, w_j)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def feature_rmatvec(A_j, r, use_kernel: bool = True):
    """g_j = A_j^T @ r (the partial-gradient data term)."""
    if use_kernel:
        return _frmv(A_j, r)
    return ref.feature_rmatvec_ref(A_j, r)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def feature_hvp(A_j, h, av, use_kernel: bool = True):
    """u_j = A_j^T (h ⊙ av) (the fused HVP data term)."""
    if use_kernel:
        return _fhvp(A_j, h, av)
    return ref.feature_hvp_ref(A_j, h, av)


@functools.partial(jax.jit, static_argnames=("n", "lam", "use_kernel"))
def fused_pgrad(A_j, r, w_j, mask_j, n, lam, use_kernel: bool = True):
    """g_j = (A_j^T r / n + lam w_j) * mask_j (epilogue-fused pgrad)."""
    if use_kernel:
        return _fpg(A_j, r, w_j, mask_j, n=n, lam=lam)
    return ref.fused_pgrad_ref(A_j, r, w_j, mask_j, n=n, lam=lam)


@functools.partial(jax.jit, static_argnames=("n", "lam", "use_kernel"))
def fused_phvp(A_j, h, av, v_j, mask_j, n, lam, use_kernel: bool = True):
    """u_j = (A_j^T (h ⊙ av) / n + lam v_j) * mask_j (fused HVP)."""
    if use_kernel:
        return _fph(A_j, h, av, v_j, mask_j, n=n, lam=lam)
    return ref.fused_phvp_ref(A_j, h, av, v_j, mask_j, n=n, lam=lam)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def tridiag_matvec(diag, off, v, use_kernel: bool = True):
    """Banded tridiagonal matvec (hard-instance Hessian apply)."""
    if use_kernel:
        return _tdmv(diag, off, v)
    return ref.tridiag_matvec_ref(diag, off, v)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def moe_combine(expert_out, combine_w, use_kernel: bool = True):
    """Top-k weighted expert-output combine."""
    if use_kernel:
        return _moec(expert_out, combine_w)
    return ref.moe_combine_ref(expert_out, combine_w)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def flash_decode(q, k, v, bias, use_kernel: bool = True):
    """Streaming one-token attention against a long KV cache."""
    if use_kernel:
        return _fdec(q, k, v, bias)
    return ref.flash_decode_ref(q, k, v, bias)
