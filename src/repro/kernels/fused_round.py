"""Fused whole-round Pallas kernels with in-kernel wire channels.

``feature_matvec``/``feature_rmatvec``/``feature_hvp`` already fuse one
GEMV each; every algorithm in the paper's family F^{lam,L} still
composes its round from two of them plus jnp epilogues, so machine j's
A_j block crosses HBM twice per round — and a lossy wire channel
(``core.channel``) costs a third pass over the upload vector.  The
kernels here collapse all of that:

* ``make_round_step`` builds ONE kernel per round-step, grid over the
  machine axis, with machine j's whole padded A_j block VMEM-resident:

      lg   = l'(z, y)                       (in-kernel curvature term)
      g    = (A_j^T lg) / n + lam y_j       (masked partial gradient)
      x,y  = update(x_j, y_j, g, coeff)     (the algorithm's block-local
                                             update, traced into the body)
      zloc = A_j y_new                      (next round's response summand)
      out  = channel_stage(rnd + 1)(zloc)   (the UPLOAD, already on-wire)

  so A_j is read from HBM exactly once per round-step and the channel
  transform (fp16/bf16/int8 stochastic rounding with the hash-derived
  offsets of ``core.channel``) happens in the same pass that emits the
  upload vector.  The communicator reduces it with
  ``reduce_all(..., pretransformed=True)`` — record metadata, wire
  pricing and fault injection are byte-identical to the composed path.

* ``fused_pgrad``/``fused_phvp`` are the composed-oracle fallbacks for
  round shapes the whole-round kernel cannot rotate (DISCO-F's CG
  interleaves scalar reduces between the HVP and the next matvec, so a
  one-A-read round is impossible there): the same accumulation grid as
  ``feature_rmatvec``/``feature_hvp`` with the gradient epilogue
  (``/n + lam v``, block mask) folded into the last contraction block —
  one A-read per oracle instead of an extra d-vector HBM round-trip.

Bit-identity contract: wherever ``round_step_supported`` admits a cell,
the fused step's iterates, uploads and ledger stream are bit-identical
to the composed ``kernel`` backend.  That holds because (a) the single
whole-block dots see the same padded operands as the one-block tilings
of ``feature_matvec``/``feature_rmatvec`` (the support gate caps blocks
at one tile), (b) the epilogue/update arithmetic runs in the same f32
op order as the composed jnp epilogues, and (c) ``Channel.apply`` is
invoked verbatim inside the kernel body — elementwise transforms do not
care that the payload is the padded (n_pad, 1) column (int8's
per-message max is unchanged by |0| padding; pad lanes are sliced off
before the wire).  ``tests/test_ledger_invariance.py`` and
``tests/test_kernel_properties.py`` pin all of this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .feature_matvec import (BLOCK_B, BLOCK_D, BLOCK_N, _acc_dtype,
                             _interp, _pad2, _rup)
from ..core.channel import Channel, ScheduledChannel

# The whole-round kernel keeps machine j's entire padded A_j block in
# one VMEM tile, so it only engages when that tile is a single
# MXU-aligned block (which is also what makes its dots bit-identical to
# the composed kernels' one-block tilings).
ROUND_STEP_MAX_N = BLOCK_N
ROUND_STEP_MAX_D = BLOCK_D

# VMEM budget for one grid step (A block + vectors, double-buffered).
# ~16 MiB/core on current TPUs; stay at half to leave room for the
# scratch the compiler adds.
ROUND_STEP_VMEM_BYTES = 8 * 1024 * 1024

# Channel stages the kernel can reproduce bit-identically in-body:
# everything elementwise (plus int8's per-message max).  topk needs
# lax.top_k over the full message — not a Mosaic-friendly shape — so
# topk cells fall back to the composed path.
IN_KERNEL_STAGES = ("identity", "fp16", "bf16", "int8")


def channel_stages(channel):
    """The fixed stages an in-kernel wire must reproduce, or ``None``
    when any stage needs ops outside the kernel's reach."""
    if isinstance(channel, ScheduledChannel):
        stages = tuple(channel.stages)
    elif isinstance(channel, Channel):
        stages = ((0, channel),)
    else:
        return None     # unresolved gap spec, or not a channel at all
    if all(st.kind in IN_KERNEL_STAGES for _, st in stages):
        return stages
    return None


def round_step_fits(n: int, d_max: int, itemsize: int = 4) -> bool:
    """Whole-A_j-resident is only sound when the padded block is a
    single MXU tile inside the VMEM budget."""
    n_pad, d_pad = _rup(n), _rup(d_max)
    if n_pad > ROUND_STEP_MAX_N or d_pad > ROUND_STEP_MAX_D:
        return False
    vecs = 4 * d_pad + 4 * n_pad           # x/y/mask/g + z/y_data/zloc/nmask
    return 2 * (n_pad * d_pad + vecs) * itemsize <= ROUND_STEP_VMEM_BYTES


def _apply_stage(stages, x, rnd):
    """The channel transform at round ``rnd`` inside a kernel body.

    Single stage: static dispatch.  Multi-stage schedule: a where-select
    over the (static) stage table — every stage's transform is computed
    on the VMEM-resident block and the active one selected lane-wise,
    which is bit-identical to ``ScheduledChannel.apply``'s ``lax.switch``
    without asking Mosaic for multi-branch control flow."""
    if len(stages) == 1:
        return stages[0][1].apply(x)
    rnd = jnp.asarray(rnd, jnp.int32)
    starts = jnp.asarray([s for s, _ in stages[1:]], dtype=jnp.int32)
    idx = jnp.sum(rnd >= starts)
    out = stages[0][1].apply(x)
    for i, (_, stage) in enumerate(stages[1:], start=1):
        out = jnp.where(idx == i, stage.apply(x), out)
    return out


def make_round_step(A_stk, mask, y_data, loss, *, n: int, lam: float,
                    update, channel, interpret: bool | None = None):
    """Build the fused whole-round step for one ``LocalDistERM`` cell.

    A_stk: (m, n, d_max) stacked feature blocks; mask: (m, d_max) valid-
    coordinate mask; y_data: (n,) labels; ``update(x, y, g, coeff) ->
    (x_new, y_new)`` is the algorithm's block-local update (elementwise,
    traced into the kernel body); ``channel`` the communicator's wire
    channel (must pass ``channel_stages``).

    Returns ``step(z, x_stk, y_stk, coeff, rnd) -> (x_new, y_new,
    zloc_next)`` where ``z`` is this round's reduced response, carries
    are (m, d_max), ``rnd`` is the current round index (concrete or
    traced) and ``zloc_next`` (m, n) is next round's per-machine upload
    with the round-``rnd+1`` channel stage already applied.
    """
    stages = channel_stages(channel)
    if stages is None:
        raise ValueError(f"channel {getattr(channel, 'name', channel)!r} "
                         f"has no in-kernel stage set")
    m, n_rows, d_max = A_stk.shape
    assert n_rows == n
    n_pad, d_pad = _rup(n), _rup(d_max)
    A_p = jnp.pad(jnp.asarray(A_stk, jnp.float32),
                  ((0, 0), (0, n_pad - n), (0, d_pad - d_max)))
    mask_p = jnp.pad(jnp.asarray(mask, jnp.float32),
                     ((0, 0), (0, d_pad - d_max)))
    yd_p = jnp.pad(jnp.asarray(y_data, jnp.float32)[:, None],
                   ((0, n_pad - n), (0, 0)))
    # pad rows contribute nothing to the dots (A pad rows are zero), but
    # a custom loss could emit non-finite l'(0, 0); mask them to keep
    # 0 * lg finite.
    nmask = jnp.pad(jnp.ones((n, 1), jnp.float32),
                    ((0, n_pad - n), (0, 0)))

    def _round_math(a, z, yd, nm, x, y, mk, coeff, rnd):
        lg = loss.grad(z, yd) * nm
        g = jnp.dot(a.T, lg, preferred_element_type=jnp.float32).T / n
        g = (g + lam * y) * mk
        x_new, y_new = update(x, y, g, coeff)
        zloc = jnp.dot(a, y_new.T, preferred_element_type=jnp.float32)
        zloc = _apply_stage(stages, zloc, rnd + 1)
        return x_new, y_new, zloc.T

    # Algorithm updates close over jnp scalars (step sizes, momentum
    # coefficients — f32-wrapped exactly so execute_batch can hoist
    # them), and the stage table materializes small index arrays.  A
    # Pallas body cannot capture such constants, so trace the round
    # math once, hoist the jaxpr's consts, and feed each back in as an
    # extra kernel operand (reshaped to a (1, size) VMEM row).  The
    # body replays the jaxpr verbatim — same ops, same order, so the
    # bit-identity argument above is unchanged.
    z = jnp.zeros
    closed = jax.make_jaxpr(_round_math)(
        z((n_pad, d_pad), jnp.float32),
        z((n_pad, 1), jnp.float32), z((n_pad, 1), jnp.float32),
        z((n_pad, 1), jnp.float32), z((1, d_pad), jnp.float32),
        z((1, d_pad), jnp.float32), z((1, d_pad), jnp.float32),
        jnp.float32(0.0), jnp.int32(0))
    consts = [jnp.asarray(c) for c in closed.consts]
    const_rows = [c.reshape(1, -1) for c in consts]
    n_fixed = 9

    n_args = len(closed.jaxpr.invars)

    def math_fn(*args):            # (*round_args, *consts) -> 3 arrays
        return jax.core.eval_jaxpr(closed.jaxpr, args[n_args:],
                                   *args[:n_args])

    def body(*refs):
        (a_ref, z_ref, yd_ref, nm_ref, x_ref, y_ref, mk_ref,
         cf_ref, rn_ref) = refs[:n_fixed]
        c_refs = refs[n_fixed:n_fixed + len(consts)]
        xo_ref, yo_ref, zo_ref = refs[n_fixed + len(consts):]
        cvals = [cr[0, 0] if c.ndim == 0 else cr[...].reshape(c.shape)
                 for cr, c in zip(c_refs, consts)]
        x_new, y_new, zloc_t = math_fn(
            a_ref[0], z_ref[...], yd_ref[...], nm_ref[...],
            x_ref[...], y_ref[...], mk_ref[...],
            cf_ref[0, 0], rn_ref[0, 0], *cvals)
        xo_ref[...] = x_new
        yo_ref[...] = y_new
        zo_ref[...] = zloc_t

    call = pl.pallas_call(
        body,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, n_pad, d_pad), lambda j: (j, 0, 0)),
            pl.BlockSpec((n_pad, 1), lambda j: (0, 0)),
            pl.BlockSpec((n_pad, 1), lambda j: (0, 0)),
            pl.BlockSpec((n_pad, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, d_pad), lambda j: (j, 0)),
            pl.BlockSpec((1, d_pad), lambda j: (j, 0)),
            pl.BlockSpec((1, d_pad), lambda j: (j, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ] + [pl.BlockSpec(c.shape, lambda j: (0, 0))
             for c in const_rows],
        out_specs=[
            pl.BlockSpec((1, d_pad), lambda j: (j, 0)),
            pl.BlockSpec((1, d_pad), lambda j: (j, 0)),
            pl.BlockSpec((1, n_pad), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((m, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((m, n_pad), jnp.float32),
        ],
        interpret=_interp(interpret),
    )

    # The cell's data (A_p, labels, masks, hoisted algorithm consts)
    # enters the jitted step as ARGUMENTS, not closure captures: under
    # an outer trace (``api.batch``'s ``make_jaxpr`` split) argument
    # values surface as outer-jaxpr consts that execute_batch stacks
    # per cell, while captures would be baked inside the pjit equation
    # and every grouped cell would silently replay the first cell's
    # data.
    @jax.jit
    def _step(A_p, yd_p, nmask, mask_p, crows, z, x_stk, y_stk, coeff,
              rnd):
        z_col = jnp.asarray(z, jnp.float32)[:, None]
        z_p = jnp.pad(z_col, ((0, n_pad - n), (0, 0)))
        x_p = _pad2(jnp.asarray(x_stk, jnp.float32), 1, d_pad)
        y_p = _pad2(jnp.asarray(y_stk, jnp.float32), 1, d_pad)
        cf = jnp.asarray(coeff, jnp.float32).reshape(1, 1)
        rn = jnp.asarray(rnd, jnp.int32).reshape(1, 1)
        x_new, y_new, zloc = call(A_p, z_p, yd_p, nmask, x_p, y_p,
                                  mask_p, cf, rn, *crows)
        return (x_new[:, :d_max], y_new[:, :d_max], zloc[:, :n])

    def step(z, x_stk, y_stk, coeff, rnd):
        return _step(A_p, yd_p, nmask, mask_p, tuple(const_rows),
                     z, x_stk, y_stk, coeff, rnd)

    return step


# --------------------------------------------------------------------------
# Epilogue-fused composed oracles (the fallback / DISCO-F CG variant)
# --------------------------------------------------------------------------

def _pgrad_kernel(a_ref, r_ref, w_ref, mk_ref, o_ref, *, n, lam):
    """Grid (d_blocks, b_blocks, n_blocks): o[j,b] += A[i,j]^T @ r[i,b]
    with the gradient epilogue (o/n + lam w) * mask folded into the last
    contraction block, so the partial gradient never round-trips HBM
    between the reduction and its scaling."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...].T, r_ref[...],
                          preferred_element_type=o_ref.dtype)

    @pl.when(i == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] = (o_ref[...] / n + lam * w_ref[...]) * mk_ref[...]


def fused_pgrad(A_j, r, w_j, mask_j, *, n: int, lam: float,
                block_n: int = BLOCK_N, block_d: int = BLOCK_D,
                block_b: int = BLOCK_B, interpret: bool | None = None):
    """g_j = (A_j^T r / n + lam w_j) * mask_j in one accumulation pass.

    A_j: (n_rows, d_j); r: (n_rows,) or (n_rows, B); w_j like the
    output; mask_j: (d_j,).  ``n`` is the divisor (the global sample
    count — it need not equal ``n_rows``).
    """
    squeeze = r.ndim == 1
    if squeeze:
        r = r[:, None]
        w_j = w_j[:, None]
    n_rows, dj = A_j.shape
    b = r.shape[1]
    bn, bd = min(block_n, _rup(n_rows)), min(block_d, _rup(dj))
    bb = min(block_b, _rup(b))
    A_p = _pad2(A_j, bn, bd)
    r_p = _pad2(r, bn, bb)
    w_p = _pad2(w_j.astype(A_j.dtype), bd, bb)
    mk_p = _pad2(mask_j[:, None].astype(A_j.dtype), bd, 1)
    grid = (A_p.shape[1] // bd, r_p.shape[1] // bb, A_p.shape[0] // bn)
    out = pl.pallas_call(
        functools.partial(_pgrad_kernel, n=n, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, k, i: (i, j)),
            pl.BlockSpec((bn, bb), lambda j, k, i: (i, k)),
            pl.BlockSpec((bd, bb), lambda j, k, i: (j, k)),
            pl.BlockSpec((bd, 1), lambda j, k, i: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bd, bb), lambda j, k, i: (j, k)),
        out_shape=jax.ShapeDtypeStruct((A_p.shape[1], r_p.shape[1]),
                                       _acc_dtype(A_j.dtype)),
        interpret=_interp(interpret),
    )(A_p, r_p, w_p, mk_p)
    out = out[:dj, :b].astype(A_j.dtype)
    return out[:, 0] if squeeze else out


def _phvp_kernel(a_ref, h_ref, r_ref, v_ref, mk_ref, o_ref, *, n, lam):
    """Grid (d_blocks, b_blocks, n_blocks): o[j,b] += A[i,j]^T (h[i] ⊙
    r[i,b]) with the HVP epilogue (o/n + lam v) * mask folded into the
    last contraction block — DISCO-F's CG applies this every inner
    iteration, so the saved d-vector round-trip compounds."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...].T, h_ref[...] * r_ref[...],
                          preferred_element_type=o_ref.dtype)

    @pl.when(i == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] = (o_ref[...] / n + lam * v_ref[...]) * mk_ref[...]


def fused_phvp(A_j, h, av, v_j, mask_j, *, n: int, lam: float,
               block_n: int = BLOCK_N, block_d: int = BLOCK_D,
               block_b: int = BLOCK_B, interpret: bool | None = None):
    """u_j = (A_j^T (h ⊙ av) / n + lam v_j) * mask_j in one fused pass.

    A_j: (n_rows, d_j); h: (n_rows,); av: (n_rows,) or (n_rows, B);
    v_j like the output; mask_j: (d_j,).
    """
    squeeze = av.ndim == 1
    if squeeze:
        av = av[:, None]
        v_j = v_j[:, None]
    n_rows, dj = A_j.shape
    b = av.shape[1]
    bn, bd = min(block_n, _rup(n_rows)), min(block_d, _rup(dj))
    bb = min(block_b, _rup(b))
    A_p = _pad2(A_j, bn, bd)
    h_p = _pad2(h[:, None], bn, 1)
    r_p = _pad2(av, bn, bb)
    v_p = _pad2(v_j.astype(A_j.dtype), bd, bb)
    mk_p = _pad2(mask_j[:, None].astype(A_j.dtype), bd, 1)
    grid = (A_p.shape[1] // bd, r_p.shape[1] // bb, A_p.shape[0] // bn)
    out = pl.pallas_call(
        functools.partial(_phvp_kernel, n=n, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, k, i: (i, j)),
            pl.BlockSpec((bn, 1), lambda j, k, i: (i, 0)),
            pl.BlockSpec((bn, bb), lambda j, k, i: (i, k)),
            pl.BlockSpec((bd, bb), lambda j, k, i: (j, k)),
            pl.BlockSpec((bd, 1), lambda j, k, i: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bd, bb), lambda j, k, i: (j, k)),
        out_shape=jax.ShapeDtypeStruct((A_p.shape[1], r_p.shape[1]),
                                       _acc_dtype(A_j.dtype)),
        interpret=_interp(interpret),
    )(A_p, h_p.astype(A_j.dtype), r_p, v_p, mk_p)
    out = out[:dj, :b].astype(A_j.dtype)
    return out[:, 0] if squeeze else out
