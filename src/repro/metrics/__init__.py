from .logger import MetricsLogger, StepTimer

__all__ = ["MetricsLogger", "StepTimer"]
