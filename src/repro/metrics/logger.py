"""Metrics substrate: JSONL step logs + EMA-smoothed console lines +
throughput accounting (tokens/s, step-time percentiles).

Deliberately dependency-free (no tensorboard/wandb in this offline
container); the JSONL format is trivially ingestible by either.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

import numpy as np


class StepTimer:
    """Wall-clock per-step timing with warmup exclusion and percentiles."""

    def __init__(self, warmup: int = 2):
        self.warmup = warmup
        self._times = []
        self._t0 = None
        self._count = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self):
        dt = time.perf_counter() - self._t0
        self._count += 1
        if self._count > self.warmup:
            self._times.append(dt)
        return dt

    def summary(self) -> Dict[str, float]:
        if not self._times:
            return {}
        arr = np.asarray(self._times)
        return {
            "steps_timed": len(arr),
            "mean_s": float(arr.mean()),
            "p50_s": float(np.percentile(arr, 50)),
            "p95_s": float(np.percentile(arr, 95)),
        }


class MetricsLogger:
    """Append-only JSONL metrics with EMA console summaries."""

    def __init__(self, log_dir: Optional[str] = None, ema: float = 0.9,
                 tokens_per_step: int = 0):
        self.path = None
        if log_dir:
            os.makedirs(log_dir, exist_ok=True)
            self.path = os.path.join(log_dir, "metrics.jsonl")
            self._fh = open(self.path, "a")
        self.ema_coef = ema
        self._ema: Dict[str, float] = {}
        self.tokens_per_step = tokens_per_step
        self.timer = StepTimer()

    def log(self, step: int, metrics: Dict[str, Any],
            extra: Optional[Dict[str, Any]] = None) -> Dict[str, float]:
        rec: Dict[str, Any] = {"step": step, "time": time.time()}
        for k, v in metrics.items():
            v = float(v)
            rec[k] = v
            self._ema[k] = v if k not in self._ema else \
                self.ema_coef * self._ema[k] + (1 - self.ema_coef) * v
        if extra:
            rec.update(extra)
        if self.path:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        return {k: self._ema[k] for k in metrics}

    def line(self, step: int, step_time_s: float) -> str:
        parts = [f"step {step:6d}"]
        for k, v in self._ema.items():
            parts.append(f"{k} {v:.4f}")
        parts.append(f"{step_time_s*1e3:.0f} ms/step")
        if self.tokens_per_step:
            parts.append(f"{self.tokens_per_step/step_time_s:.0f} tok/s")
        return "  ".join(parts)

    def close(self):
        if self.path:
            self._fh.close()
